"""SLO admission control + pad-row spike-leak regression (satellites of the
threaded-engine PR).

The SLO tests replay deterministically: virtual clock, injected constant
service times, and an explicit ``slo_seconds_per_work`` prior — so every
admit/reject decision is bit-reproducible.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import get_snn
from repro.core import init_snn, snn_apply
from repro.serving import EngineConfig, ServingEngine
from repro.serving.admission import (layer0_channel_weights, predict_workload,
                                     slo_filter)
from repro.serving.request import Request


def _tiny_cfg():
    return dataclasses.replace(
        get_snn("snn-mnist"), input_hw=(8, 8), conv_channels=(8, 8),
        timesteps=3, num_spe_clusters=4)


@pytest.fixture(scope="module")
def tiny():
    cfg = _tiny_cfg()
    params = init_snn(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _uniform_frames(n, cfg, value=0.5):
    h, w = cfg.input_hw
    return np.full((n, h, w, cfg.input_channels), value, np.float32)


# -- pad-row spike leakage ---------------------------------------------------

def _trained_like_params(params, bias=1.5):
    """Supra-threshold conv biases, as a trained net can have: all-zero pad
    rows now fire every timestep."""
    return {**params,
            "conv": [dict(p, b=p["b"] + bias) for p in params["conv"]]}


def test_pad_rows_fire_with_trained_params(tiny):
    """Sanity for the regression below: with supra-threshold biases a zero
    frame really does produce spikes (the leak exists to be masked)."""
    cfg, params = tiny
    params_b = _trained_like_params(params)
    zero = np.zeros((1, *cfg.input_hw, cfg.input_channels), np.float32)
    out = snn_apply(params_b, zero, cfg, backend="batched")
    assert sum(float(t) for t in out.spike_totals) > 0


def test_accumulated_spikes_match_unpadded_reference(tiny):
    """Serving 3 frames pads the micro-batch to bucket 4; with trained
    (nonzero-bias) params the pad row fires, and ``_accumulate`` must
    subtract its contribution so the engine's spike workload equals an
    unpadded forward of exactly those 3 frames."""
    cfg, params = tiny
    params_b = _trained_like_params(params)
    frames = np.clip(np.random.default_rng(2).uniform(
        0, 1, (3, *cfg.input_hw, cfg.input_channels)), 0, 1).astype(np.float32)

    eng = ServingEngine(params_b, cfg, EngineConfig(num_lanes=1, max_batch=4))
    for f in frames:
        eng.submit(f, arrival=0.0)
    eng.run()

    ref = snn_apply(params_b, frames, cfg, backend="batched")
    acc = eng.accumulated_timestep_counts()
    assert acc is not None
    for masked, want in zip(acc, ref.timestep_counts):
        np.testing.assert_allclose(masked, np.asarray(want, np.float64),
                                   rtol=1e-6, atol=1e-6)


def test_energy_metric_unaffected_by_padding(tiny):
    """Energy/image through the padded engine == through an engine whose
    bucket set matches the batch exactly (no pad rows at all)."""
    cfg, params = tiny
    params_b = _trained_like_params(params)
    frames = np.clip(np.random.default_rng(4).uniform(
        0, 1, (3, *cfg.input_hw, cfg.input_channels)), 0, 1).astype(np.float32)

    def run(buckets, max_batch):
        eng = ServingEngine(params_b, cfg, EngineConfig(
            num_lanes=1, max_batch=max_batch, buckets=buckets))
        for f in frames:
            eng.submit(f, arrival=0.0)
        return eng.run()

    padded = run((1, 2, 4, 8, 16), 4)        # 3 frames pad into bucket 4
    exact = run((1, 3), 3)                   # 3 is its own bucket: no pads
    assert padded["energy_j_per_image"] == pytest.approx(
        exact["energy_j_per_image"], rel=1e-6)


# -- SLO admission control ---------------------------------------------------

def test_slo_filter_rejects_over_budget_requests():
    reqs = [Request(rid=i, frame=np.zeros((2, 2, 1)), arrival=0.0,
                    workload=1.0, events=1.0) for i in range(10)]
    admitted, rejected, degraded = slo_filter(
        reqs, now=0.0, budget_s=0.5, seconds_per_work=0.2, num_lanes=1,
        full_timesteps=4, action="reject")
    # delay of request i (1-indexed cum work) = 0.2 * i; budget admits i <= 2
    assert [r.rid for r in admitted] == [0, 1]
    assert [r.rid for r in rejected] == list(range(2, 10))
    assert all(r.rejected for r in rejected)
    assert degraded == 0


def test_slo_filter_degrade_sheds_work_instead_of_requests():
    reqs = [Request(rid=i, frame=np.zeros((2, 2, 1)), arrival=0.0,
                    workload=1.0, events=1.0) for i in range(10)]
    admitted, rejected, degraded = slo_filter(
        reqs, now=0.0, budget_s=0.5, seconds_per_work=0.2, num_lanes=1,
        full_timesteps=4, action="degrade", degrade_timesteps=1)
    assert not rejected
    assert len(admitted) == 10 and degraded > 0
    # degraded requests carry the reduced T; the first two stay full-quality
    assert [r.timesteps for r in admitted[:2]] == [None, None]
    assert all(r.timesteps == 1 for r in admitted if r.degraded)
    # degrading shed 4x work per request, so more fit under the budget than
    # reject mode admitted at full T
    assert sum(r.timesteps is None for r in admitted) == 2


def test_slo_filter_degrade_never_drops_even_when_undegradable():
    """Degrade mode's contract is quality loss, not loss of service: a
    request that cannot be degraded any further (degrade_timesteps at or
    above its T — e.g. a T=1 network) is kept as-is, never rejected."""
    reqs = [Request(rid=i, frame=np.zeros((2, 2, 1)), arrival=0.0,
                    workload=1.0, events=1.0) for i in range(6)]
    admitted, rejected, degraded = slo_filter(
        reqs, now=0.0, budget_s=0.0, seconds_per_work=1.0, num_lanes=1,
        full_timesteps=1, action="degrade", degrade_timesteps=1)
    assert not rejected and degraded == 0
    assert [r.rid for r in admitted] == list(range(6))
    assert all(r.timesteps is None for r in admitted)


def test_slo_filter_batch_quantum_admits_more_under_tight_budget():
    """The delay model's intercept: a measured per-batch quantum is paid
    once per micro-batch, not once per request.  The historical model folded
    it into seconds_per_work, pricing a window of n requests for ~n quanta;
    with the quantum split out the marginal rate un-inflates and more
    requests fit the same budget.  Fully deterministic."""
    quantum, marginal = 0.2, 0.1
    budget = 0.61

    def reqs():
        return [Request(rid=i, frame=np.zeros((2, 2, 1)), arrival=0.0,
                        workload=1.0, events=1.0) for i in range(10)]

    # historical conservative pricing: quantum folded into the rate (the
    # first measured batch of unit work costs quantum + marginal seconds)
    folded, rej_folded, _ = slo_filter(
        reqs(), now=0.0, budget_s=budget,
        seconds_per_work=quantum + marginal, num_lanes=1,
        full_timesteps=4, action="reject")
    # intercept model: same measurements, quantum priced once per batch
    split, rej_split, _ = slo_filter(
        reqs(), now=0.0, budget_s=budget, seconds_per_work=marginal,
        batch_quantum_s=quantum, num_lanes=1,
        full_timesteps=4, action="reject")
    # folded: delay_i = 0.3 * i -> admits 2; split: 0.2 + 0.1 * i -> admits 4
    assert [r.rid for r in folded] == [0, 1]
    assert [r.rid for r in split] == [0, 1, 2, 3]
    assert len(split) > len(folded)
    assert len(rej_split) + len(split) == 10
    assert len(rej_folded) + len(folded) == 10


def test_slo_filter_chunk_quanta_prices_per_dispatch():
    """Chunked scheduling dispatches a T-timestep request ceil(T/chunk)
    times, so it pays that many batch quanta — a single-quantum price
    understates its fixed costs.  Deterministic: whole-T pricing admits the
    window, per-chunk pricing (4 quanta at T=8, chunk=2) rejects it."""
    def reqs():
        return [Request(rid=i, frame=np.zeros((2, 2, 1)), arrival=0.0,
                        workload=1.0, events=1.0) for i in range(6)]

    kw = dict(now=0.0, budget_s=0.2, seconds_per_work=0.01,
              batch_quantum_s=0.1, num_lanes=1, full_timesteps=8,
              action="reject")
    # whole-T: delay_i = 0.1 + 0.01 * i <= 0.2 for all six
    whole, rej_whole, _ = slo_filter(reqs(), **kw)
    assert [r.rid for r in whole] == list(range(6)) and not rej_whole
    # chunk=2 -> ceil(8/2) = 4 quanta: delay_i = 0.4 + 0.01 * i > 0.2
    chunked, rej_chunked, _ = slo_filter(reqs(), chunk_timesteps=2, **kw)
    assert not chunked
    assert [r.rid for r in rej_chunked] == list(range(6))


def test_slo_filter_chunk_at_or_above_t_is_whole_t_pricing():
    """chunk >= T is one dispatch, one quantum — identical decisions to
    chunk_timesteps=None."""
    def reqs():
        return [Request(rid=i, frame=np.zeros((2, 2, 1)), arrival=0.0,
                        workload=1.0, events=1.0) for i in range(8)]

    kw = dict(now=0.0, budget_s=0.14, seconds_per_work=0.01,
              batch_quantum_s=0.1, num_lanes=1, full_timesteps=8,
              action="reject")
    base, rej_base, _ = slo_filter(reqs(), **kw)
    for ct in (8, 16):
        got, rej_got, _ = slo_filter(reqs(), chunk_timesteps=ct, **kw)
        assert [r.rid for r in got] == [r.rid for r in base]
        assert [r.rid for r in rej_got] == [r.rid for r in rej_base]


def test_slo_filter_chunk_quanta_drive_degrade():
    """Under degrade action the per-chunk price pushes requests over budget
    that whole-T pricing kept at full quality — they are served degraded
    (fewer chunks), never dropped."""
    def reqs():
        return [Request(rid=i, frame=np.zeros((2, 2, 1)), arrival=0.0,
                        workload=1.0, events=1.0) for i in range(6)]

    kw = dict(now=0.0, budget_s=0.2, seconds_per_work=0.01,
              batch_quantum_s=0.1, num_lanes=1, full_timesteps=8,
              action="degrade", degrade_timesteps=2)
    whole, _, deg_whole = slo_filter(reqs(), **kw)
    assert deg_whole == 0 and all(r.timesteps is None for r in whole)
    chunked, rej, deg_chunked = slo_filter(reqs(), chunk_timesteps=2, **kw)
    assert not rej and len(chunked) == 6
    assert deg_chunked == 6
    assert all(r.timesteps == 2 for r in chunked)


def test_engine_batch_quantum_prior_admits_more(tiny):
    """EngineConfig.slo_batch_quantum_s flows into the admitter: with the
    same total first-batch cost, splitting it into quantum + marginal rate
    serves strictly more of a deterministic burst than folding it into the
    rate."""
    cfg, params = tiny
    frames = _uniform_frames(12, cfg)
    w = predict_workload(frames[0], layer0_channel_weights(params),
                         cfg.timesteps)
    quantum, marginal = 0.02, 0.002 / w

    def run(spw, q):
        eng = ServingEngine(params, cfg, EngineConfig(
            num_lanes=2, max_batch=4, latency_budget_s=0.05,
            slo_seconds_per_work=spw, slo_batch_quantum_s=q,
            slo_action="reject",
            service_time_fn=lambda lane, wall: 0.001, keep_logits=False))
        for f in frames:
            eng.submit(f, arrival=0.0)
        return eng.run()

    folded = run(quantum / w + marginal, None)
    split = run(marginal, quantum)
    assert split["served"] + split["rejected"] == len(frames)
    assert folded["served"] + folded["rejected"] == len(frames)
    assert split["served"] > folded["served"]
    # deterministic replay of the split model
    assert run(marginal, quantum)["served"] == split["served"]


def test_engine_fits_quantum_from_measured_batches(tiny):
    """With no priors the engine learns (quantum, rate) by fitting
    svc = q + m * work over measured micro-batches: injected service times
    with a known intercept are recovered by _delay_model."""
    cfg, params = tiny
    quantum, marginal = 0.01, 0.003
    eng = ServingEngine(params, cfg, EngineConfig(
        num_lanes=1, max_batch=4, keep_logits=False,
        service_time_fn=lambda lane, wall: 0.0))   # svc injected below
    # feed the fit directly with a perfectly linear sample set
    for work in (1.0, 2.0, 4.0, 8.0):
        eng._svc_samples.append((work, quantum + marginal * work))
    q, m = eng._delay_model()
    assert q == pytest.approx(quantum, rel=1e-6)
    assert m == pytest.approx(marginal, rel=1e-6)
    # a single-point sample set cannot identify the intercept: falls back
    eng2 = ServingEngine(params, cfg, EngineConfig(num_lanes=1, max_batch=4))
    eng2._svc_samples.append((1.0, 0.5))
    assert eng2._fit_delay_model() is None


def test_slo_filter_unknown_action_raises():
    with pytest.raises(ValueError, match="slo action"):
        slo_filter([], now=0.0, budget_s=1.0, seconds_per_work=1.0,
                   num_lanes=1, full_timesteps=4, action="drop")


def test_engine_unknown_slo_action_raises(tiny):
    cfg, params = tiny
    with pytest.raises(ValueError, match="slo_action"):
        ServingEngine(params, cfg, EngineConfig(slo_action="drop"))


def test_engine_zero_degrade_timesteps_rejected_at_construction(tiny):
    """A zero-timestep network cannot run; the config must fail fast, not
    crash mid-serving when the first request degrades."""
    cfg, params = tiny
    with pytest.raises(ValueError, match="degrade_timesteps"):
        ServingEngine(params, cfg, EngineConfig(
            latency_budget_s=0.01, slo_action="degrade",
            degrade_timesteps=0))


def test_requeued_requests_bypass_slo_rejection(tiny):
    """A request that was admitted, dispatched, and re-queued by a lane
    death must be served, never re-rejected — even though its waited time
    now exceeds the budget (the no-request-lost guarantee outranks the
    SLO)."""
    cfg, params = tiny

    def kill_lane0(lane, attempt):
        if lane == 0:
            raise RuntimeError("chaos: lane 0 down")

    frames = _uniform_frames(8, cfg)
    w = predict_workload(frames[0], layer0_channel_weights(params),
                         cfg.timesteps)
    eng = ServingEngine(params, cfg, EngineConfig(
        num_lanes=2, max_batch=4, max_retries=0, fault_hook=kill_lane0,
        latency_budget_s=0.01, slo_seconds_per_work=1e-9 / w,
        slo_action="reject",
        service_time_fn=lambda lane, wall: 0.05, keep_logits=False))
    for f in frames:
        eng.submit(f, arrival=0.0)
    s = eng.run()
    # lane 0's first micro-batch burned 0.05s before re-queueing: waited is
    # over the 0.01s budget, yet nothing may be dropped
    assert s["dead_lanes"] == 1
    assert s["rejected"] == 0
    assert s["served"] == len(frames)
    assert any(r.retries > 0 for r in eng.completed)


def test_engine_rejects_over_budget_and_surfaces_metric(tiny):
    """Burst over budget: the engine rejects deterministically, rejections
    surface in ServingMetrics, and every request is accounted for."""
    cfg, params = tiny
    frames = _uniform_frames(12, cfg)
    w = predict_workload(frames[0], layer0_channel_weights(params),
                         cfg.timesteps)
    budget = 0.05
    spw = budget * 2 / (w * 5)        # ~5 requests fit the budget at t=0

    def run():
        eng = ServingEngine(params, cfg, EngineConfig(
            num_lanes=2, max_batch=4, latency_budget_s=budget,
            slo_seconds_per_work=spw, slo_action="reject",
            service_time_fn=lambda lane, wall: 0.001, keep_logits=False))
        for f in frames:
            eng.submit(f, arrival=0.0)
        return eng, eng.run()

    eng, s = run()
    assert s["rejected"] > 0
    assert s["served"] + s["rejected"] == len(frames)
    assert s["rejected"] == len(eng.rejected)
    assert all(r.rejected and not r.done for r in eng.rejected)
    assert max(r.latency for r in eng.completed) <= budget
    # deterministic replay: identical admit/reject split
    _, s2 = run()
    assert (s2["served"], s2["rejected"]) == (s["served"], s["rejected"])


def test_engine_degrade_serves_everyone_with_reduced_timesteps(tiny):
    """Degrade mode sheds timesteps, not requests: everything is served,
    the over-budget tail at reduced T, and degraded logits bitwise match a
    reduced-T forward (the degraded executable is real, not a stub)."""
    cfg, params = tiny
    frames = _uniform_frames(12, cfg)
    w = predict_workload(frames[0], layer0_channel_weights(params),
                         cfg.timesteps)
    budget = 0.05
    spw = budget * 2 / (w * 5)
    eng = ServingEngine(params, cfg, EngineConfig(
        num_lanes=2, max_batch=4, latency_budget_s=budget,
        slo_seconds_per_work=spw, slo_action="degrade", degrade_timesteps=1,
        service_time_fn=lambda lane, wall: 0.001))
    for f in frames:
        eng.submit(f, arrival=0.0)
    s = eng.run()
    assert s["served"] == len(frames) and s["rejected"] == 0
    assert s["degraded"] > 0
    cfg1 = dataclasses.replace(cfg, timesteps=1)
    single = jax.jit(lambda p, x: snn_apply(p, x, cfg1, backend="batched"))
    for r in eng.completed:
        if r.degraded:
            assert r.timesteps == 1
            want = np.asarray(single(params, r.frame[None]).logits[0])
            np.testing.assert_array_equal(want, r.logits)


def test_p99_holds_under_budget_on_quick_load_trace(tiny):
    """--quick-scale overloaded Poisson trace (3x capacity): without SLO
    control p99 blows through the budget; with conservatively-priced
    admission (one batch quantum per lightest request) the served p99 stays
    under it.  Fully deterministic (virtual clock + injected service)."""
    cfg, params = tiny
    cw = layer0_channel_weights(params)
    n, svc, budget = 48, 0.004, 0.01
    frames = np.clip(np.random.default_rng(5).uniform(
        0, 1, (n, *cfg.input_hw, cfg.input_channels)), 0, 1).astype(np.float32)
    wmin = min(predict_workload(f, cw, cfg.timesteps) for f in frames)
    spw = 2.0 * svc / wmin
    capacity = 2 * 4 / svc
    arrivals = np.cumsum(
        np.random.default_rng(3).exponential(1.0 / (3.0 * capacity), n))

    def run(budget_s):
        eng = ServingEngine(params, cfg, EngineConfig(
            num_lanes=2, max_batch=4, latency_budget_s=budget_s,
            slo_seconds_per_work=spw, slo_action="reject",
            service_time_fn=lambda lane, wall: svc, keep_logits=False))
        for f, a in zip(frames, arrivals):
            eng.submit(f, arrival=float(a))
        return eng.run()

    slo = run(budget)
    unprotected = run(None)
    assert unprotected["p99_latency_s"] > budget      # overload is real
    assert slo["p99_latency_s"] <= budget
    assert slo["rejected"] > 0
    assert slo["served"] + slo["rejected"] == n


def test_no_rate_estimate_admits_everything(tiny):
    """With a budget but no prior and no service history, the admitter has
    no delay estimate yet — it must not reject blindly."""
    cfg, params = tiny
    eng = ServingEngine(params, cfg, EngineConfig(
        num_lanes=2, max_batch=4, latency_budget_s=1e-9,
        slo_action="reject", keep_logits=False))
    frames = _uniform_frames(4, cfg)
    for f in frames:
        eng.submit(f, arrival=0.0)
    s = eng.run()
    assert s["served"] == len(frames)                 # first window admits all


def test_threaded_engine_honors_slo(tiny):
    """SLO admission composes with the threaded engine: an absurdly tight
    budget with an explicit prior rejects the whole burst tail."""
    cfg, params = tiny
    frames = _uniform_frames(10, cfg)
    w = predict_workload(frames[0], layer0_channel_weights(params),
                         cfg.timesteps)
    eng = ServingEngine(params, cfg, EngineConfig(
        num_lanes=2, max_batch=4, threaded=True,
        latency_budget_s=1e-4, slo_seconds_per_work=1.0 / w,
        slo_action="reject", keep_logits=False))
    for f in frames:
        eng.submit(f, arrival=0.0)
    s = eng.run()
    assert s["served"] + s["rejected"] == len(frames)
    assert s["rejected"] > 0
