"""The threaded serving engine: real worker-thread lanes on the wall clock.

Chaos discipline: thread interleavings are nondeterministic, so these tests
assert *conservation and ordering invariants* (no request lost, none served
twice, FIFO at window granularity, bitwise-correct logits) rather than exact
schedules; the bit-exact replay guarantee is asserted on the VirtualClock
path, which the threaded engine shares its admission/binning code with.
"""
import dataclasses
import threading

import jax
import numpy as np
import pytest

from repro.config import get_snn
from repro.core import init_snn, snn_apply
from repro.serving import EngineConfig, ServingEngine, VirtualClock, WallClock


def _tiny_cfg():
    return dataclasses.replace(
        get_snn("snn-mnist"), input_hw=(8, 8), conv_channels=(8, 8),
        timesteps=3, num_spe_clusters=4)


@pytest.fixture(scope="module")
def tiny():
    cfg = _tiny_cfg()
    params = init_snn(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _skewed_frames(n, cfg, seed=0, sigma=1.2):
    rng = np.random.default_rng(seed)
    h, w = cfg.input_hw
    x = rng.uniform(0, 1, (n, h, w, cfg.input_channels))
    scale = rng.lognormal(-0.5, sigma, (n, 1, 1, 1))
    return np.clip(x * scale, 0, 1).astype(np.float32)


def _submit_burst(eng, frames, heavy_first=True, gap=0.0):
    """Skewed burst: heaviest requests first (the adversarial arrival order
    the FIFO baseline handles worst)."""
    order = (np.argsort(-frames.sum(axis=(1, 2, 3))) if heavy_first
             else np.arange(len(frames)))
    return [eng.submit(frames[i], arrival=gap * k)
            for k, i in enumerate(order)]


def _assert_conserved(eng, rids):
    """No request lost, none served twice."""
    done = [r.rid for r in eng.completed]
    assert len(done) == len(set(done)), "a request was served twice"
    assert sorted(done) == sorted(rids), "a request was lost"
    assert all(r.finish >= r.start >= 0 for r in eng.completed)


def _assert_fifo_windows(eng):
    """FIFO preserved at window granularity: among never-retried requests, a
    later arrival never lands in an earlier admission window (retried
    micro-batches legitimately re-enter at the head of a later window)."""
    clean = sorted((r for r in eng.completed if r.retries == 0),
                   key=lambda r: (r.arrival, r.rid))
    windows = [r.window for r in clean]
    assert windows == sorted(windows)


# -- clocks ------------------------------------------------------------------

def test_virtual_clock_advances_monotonically():
    c = VirtualClock()
    assert c.now() == 0.0 and c.virtual
    c.advance_to(1.5)
    c.advance_to(0.5)                    # backward moves are no-ops
    assert c.now() == 1.5
    c.sleep_until(2.0)                   # virtual sleeping is advancing
    assert c.now() == 2.0


def test_wall_clock_tracks_real_time():
    c = WallClock()
    assert not c.virtual
    t0 = c.now()
    c.sleep_until(t0 + 0.02)
    assert c.now() >= t0 + 0.02


# -- threaded engine ---------------------------------------------------------

def test_threaded_serves_all_bitwise_identical_to_unbatched(tiny):
    """Worker-thread lanes must not perturb any request's result: per-request
    logits == jitted unbatched snn_apply, bitwise, whatever the
    nondeterministic window composition was."""
    cfg, params = tiny
    eng = ServingEngine(params, cfg, EngineConfig(
        num_lanes=2, max_batch=4, threaded=True))
    frames = _skewed_frames(12, cfg)
    rids = _submit_burst(eng, frames, gap=0.0005)
    s = eng.run()
    assert s["served"] == len(rids)
    _assert_conserved(eng, rids)
    _assert_fifo_windows(eng)
    single = jax.jit(lambda p, x: snn_apply(p, x, cfg, backend="batched"))
    by_rid = {r.rid: r for r in eng.completed}
    frames_by_rid = {rid: f for rid, f in
                     zip(rids, frames[np.argsort(-frames.sum(axis=(1, 2, 3)))])}
    for rid, r in by_rid.items():
        want = np.asarray(single(params, frames_by_rid[rid][None]).logits[0])
        np.testing.assert_array_equal(want, r.logits)


def test_threaded_latencies_are_wall_positive(tiny):
    cfg, params = tiny
    eng = ServingEngine(params, cfg, EngineConfig(
        num_lanes=2, max_batch=4, threaded=True, keep_logits=False))
    _submit_burst(eng, _skewed_frames(8, cfg), gap=0.001)
    s = eng.run()
    assert s["served"] == 8
    assert s["p50_latency_s"] > 0 and s["p99_latency_s"] >= s["p50_latency_s"]
    assert s["fps"] > 0


def test_threaded_multi_lane_rounds_record_wall_balance(tiny):
    """Rounds that ran >= 2 micro-batches must record measured wall-time
    balance samples (not leave the vacuous 1.0 default)."""
    cfg, params = tiny
    eng = ServingEngine(params, cfg, EngineConfig(
        num_lanes=2, max_batch=4, threaded=True, keep_logits=False))
    _submit_burst(eng, _skewed_frames(16, cfg))
    s = eng.run()
    assert s["served"] == 16
    assert len(eng.metrics.wall_balances) > 0
    assert 0 < s["wall_balance"] <= 1.0


def test_threaded_lane_caches_share_warm_executables(tiny):
    """Per-lane caches are forks of one warmed cache: identical programs
    compile once, not once per lane."""
    cfg, params = tiny
    eng = ServingEngine(params, cfg, EngineConfig(
        num_lanes=3, max_batch=4, buckets=(2, 4), threaded=True,
        keep_logits=False))
    _submit_burst(eng, _skewed_frames(8, cfg))
    s = eng.run()
    assert s["served"] == 8
    # shared cache: buckets 2 and 4 at full T, + the bucket-1 pad profile;
    # the 3 lane forks add nothing
    assert s["compiles"] == 3


def test_threaded_chaos_lane_killed_mid_flight(tiny):
    """Kill lane 0 mid-flight (the fault fires on the worker thread, inside
    the retry loop, while its micro-batch is in flight): the batch drains
    back through the completion queue, survivors serve everything — no
    request lost or double-served, FIFO preserved within windows."""
    cfg, params = tiny
    calls = {"n": 0}
    lock = threading.Lock()

    def kill_lane0(lane, attempt):
        if lane == 0:
            with lock:
                calls["n"] += 1
            raise RuntimeError("chaos: lane 0 down")

    eng = ServingEngine(params, cfg, EngineConfig(
        num_lanes=2, max_batch=2, max_retries=1, threaded=True,
        fault_hook=kill_lane0))
    frames = _skewed_frames(10, cfg, sigma=1.5)
    rids = _submit_burst(eng, frames)
    s = eng.run()
    assert s["served"] == len(rids)
    _assert_conserved(eng, rids)
    _assert_fifo_windows(eng)
    assert s["dead_lanes"] == 1
    assert all(r.lane == 1 for r in eng.completed)
    if calls["n"]:                       # lane 0 got work before it died
        assert s["retries"] > 0
        assert calls["n"] == 2           # initial attempt + 1 retry


def test_threaded_retry_backoff_absorbs_transient_fault(tiny):
    """``EngineConfig.retry_backoff_s`` plumbs through to the lanes' retry
    policy: a once-per-lane transient fault is retried after the backoff
    and every request still completes."""
    cfg, params = tiny
    tripped = set()
    lock = threading.Lock()

    def flake_once(lane, attempt):
        with lock:
            if lane not in tripped:
                tripped.add(lane)
                raise RuntimeError("chaos: transient flake")

    eng = ServingEngine(params, cfg, EngineConfig(
        num_lanes=2, max_batch=2, max_retries=2, retry_backoff_s=0.005,
        threaded=True, fault_hook=flake_once, keep_logits=False))
    assert eng.dispatcher.retry.backoff_s == 0.005
    rids = _submit_burst(eng, _skewed_frames(6, cfg))
    s = eng.run()
    _assert_conserved(eng, rids)
    assert s["served"] == len(rids)
    assert s["retries"] > 0 and s["dead_lanes"] == 0


def test_threaded_all_lanes_dead_raises(tiny):
    cfg, params = tiny

    def outage(lane, attempt):
        raise RuntimeError("chaos: total outage")

    eng = ServingEngine(params, cfg, EngineConfig(
        num_lanes=2, max_batch=2, max_retries=0, threaded=True,
        fault_hook=outage))
    eng.submit(_skewed_frames(1, cfg)[0], arrival=0.0)
    with pytest.raises(RuntimeError, match="lanes failed"):
        eng.run()


def test_virtual_replay_is_deterministic_under_chaos(tiny):
    """The same chaos scenario on the VirtualClock replays bit-identically:
    identical summaries and identical per-request (lane, window, finish)
    assignments across runs — the deterministic-replay half of the Clock
    contract."""
    cfg, params = tiny

    def run_once():
        def kill_lane0(lane, attempt):
            if lane == 0:
                raise RuntimeError("chaos: lane 0 down")

        eng = ServingEngine(params, cfg, EngineConfig(
            num_lanes=2, max_batch=2, max_retries=1, keep_logits=False,
            fault_hook=kill_lane0,
            service_time_fn=lambda lane, wall: 0.01 * (lane + 1)))
        frames = _skewed_frames(10, cfg, sigma=1.5)
        rids = _submit_burst(eng, frames, gap=0.003)
        s = eng.run()
        _assert_conserved(eng, rids)
        trace = [(r.rid, r.lane, r.window, r.start, r.finish)
                 for r in sorted(eng.completed, key=lambda r: r.rid)]
        return s, trace

    s1, t1 = run_once()
    s2, t2 = run_once()
    assert t1 == t2
    assert {k: v for k, v in s1.items()} == {k: v for k, v in s2.items()}


@pytest.mark.slow
def test_threaded_soak_random_transient_faults(tiny):
    """Soak: hundreds of requests, random transient faults on every lane
    (the retry budget absorbs them), conservation + spot-checked bitwise
    logits.  Nightly CI runs this with the rest of the slow suite."""
    cfg, params = tiny
    rng = np.random.default_rng(7)
    lock = threading.Lock()

    def flaky(lane, attempt):
        with lock:
            roll = rng.random()
        if roll < 0.25:
            raise RuntimeError("chaos: transient flake")

    eng = ServingEngine(params, cfg, EngineConfig(
        num_lanes=3, max_batch=4, max_retries=6, threaded=True,
        fault_hook=flaky))
    frames = _skewed_frames(144, cfg, sigma=1.5)
    rids = _submit_burst(eng, frames, gap=0.0002)
    s = eng.run()
    _assert_conserved(eng, rids)
    _assert_fifo_windows(eng)
    assert s["served"] == len(rids)
    single = jax.jit(lambda p, x: snn_apply(p, x, cfg, backend="batched"))
    order = np.argsort(-frames.sum(axis=(1, 2, 3)))
    frames_by_rid = {rid: frames[i] for rid, i in zip(rids, order)}
    for r in eng.completed[:: max(1, len(eng.completed) // 12)]:
        want = np.asarray(single(params, frames_by_rid[r.rid][None]).logits[0])
        np.testing.assert_array_equal(want, r.logits)
