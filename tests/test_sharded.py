"""Distributed-equivalence tests: run in a subprocess with 8 fake devices
(smoke tests elsewhere must keep seeing 1 device, so the device-count flag
is isolated here)."""
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # heavyweight; excluded from default tier-1 run

_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.config import get_arch, reduced
from repro.models import transformer, lm
from repro.models.layers import moe as moe_mod
from repro.sharding.context import ShardingCtx, use_sharding
mesh = jax.make_mesh((2, 4), ("data", "model"))
"""


def _run(body: str):
    code = _PRELUDE + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


def test_moe_sharded_matches_local():
    _run("""
    cfg = reduced(get_arch("deepseek-moe-16b"))
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=1000.0))
    key = jax.random.PRNGKey(0)
    params = moe_mod.init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    local, aux_l = moe_mod.apply_local(params, x, cfg)
    ctx = ShardingCtx(mesh)
    with use_sharding(ctx), mesh:
        shard, aux_s = jax.jit(lambda p, x: moe_mod.apply(p, x, cfg))(params, x)
    err = float(jnp.abs(local - shard).max())
    scale = float(jnp.abs(local).max())
    assert err < 1e-4 * max(1.0, scale), (err, scale)
    print("moe equivalence ok", err)
    """)


def test_train_step_sharded_matches_single_device():
    _run("""
    cfg = reduced(get_arch("qwen2.5-3b"))
    key = jax.random.PRNGKey(0)
    state = lm.init_train_state(key, cfg)
    B, S = 4, 32
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(7), (B, S), 0,
                                          cfg.vocab_size)}
    step = lm.make_train_step(cfg, total_steps=100)
    _, m_single = jax.jit(step)(state, batch)

    from repro.sharding import partitioning
    ctx = ShardingCtx(mesh)
    with use_sharding(ctx), mesh:
        st_sh = partitioning.train_state_shardings(ctx, cfg)
        b_sh = partitioning.batch_shardings(
            ctx, {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                  for k, v in batch.items()})
        state_p = jax.device_put(state, st_sh)
        batch_p = {k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}
        _, m_shard = jax.jit(step, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, None))(state_p, batch_p)
    d = abs(float(m_single["loss"]) - float(m_shard["loss"]))
    assert d < 1e-3, d
    print("train equivalence ok", d)
    """)


def test_decode_sharded_matches_single_device():
    _run("""
    cfg = reduced(get_arch("gemma3-4b"))
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    B, S = 4, 32
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S + 1), 0,
                              cfg.vocab_size)
    _, caches = transformer.prefill(params, cfg, tokens=toks[:, :S],
                                    remat=False, cache_dtype=jnp.float32,
                                    max_len=S + 4)
    want, _ = transformer.decode_step(params, caches, cfg,
                                      token=toks[:, S:], pos=jnp.asarray(S))
    ctx = ShardingCtx(mesh)
    with use_sharding(ctx), mesh:
        got, _ = jax.jit(lambda p, c, t: transformer.decode_step(
            p, c, cfg, token=t, pos=jnp.asarray(S)))(params, caches, toks[:, S:])
    err = float(jnp.abs(want - got).max())
    assert err < 1e-3 * max(1.0, float(jnp.abs(want).max())), err
    print("decode equivalence ok", err)
    """)


def test_compressed_psum_exact():
    _run("""
    from repro.optim.compression import compressed_psum
    vals = jnp.stack([jnp.full((4,), float(i + 1)) for i in range(8)])
    out = jax.shard_map(lambda x: compressed_psum(x[0], "data"),
                        mesh=jax.make_mesh((8,), ("data",)),
                        in_specs=P("data"), out_specs=P())(vals)
    np.testing.assert_allclose(np.asarray(out), 36.0, rtol=1e-2)
    print("compressed psum ok")
    """)


def test_moe_ep2d_matches_local():
    """2D expert parallelism (fp8 a2a dispatch, local combine) == oracle."""
    _run("""
    mesh16 = jax.make_mesh((4, 4), ("data", "model"))
    from repro.sharding.context import make_rules
    cfg = reduced(get_arch("deepseek-moe-16b"))
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, num_experts=16, capacity_factor=1000.0))
    key = jax.random.PRNGKey(0)
    params = moe_mod.init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model))
    local, _ = moe_mod.apply_local(params, x, cfg)
    ctx = ShardingCtx(mesh16, make_rules("ep2d"))
    with use_sharding(ctx), mesh16:
        shard, _ = jax.jit(lambda p, x: moe_mod.apply(p, x, cfg))(params, x)
        g = jax.jit(jax.grad(lambda p: moe_mod.apply(p, x, cfg)[0].sum()))(params)
    err = float(jnp.abs(local - shard).max())
    assert err < 1e-4 * max(1.0, float(jnp.abs(local).max())), err
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(g))
    print("ep2d equivalence ok", err)
    """)


def test_moe_ep2d_zero_batch_over_model():
    """ep2d_zero profile: batch sharded over every axis, experts 2D-EP."""
    _run("""
    mesh16 = jax.make_mesh((4, 4), ("data", "model"))
    from repro.sharding.context import make_rules
    cfg = reduced(get_arch("deepseek-moe-16b"))
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, num_experts=16, capacity_factor=1000.0))
    params = moe_mod.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 16, cfg.d_model))
    local, _ = moe_mod.apply_local(params, x, cfg)
    ctx = ShardingCtx(mesh16, make_rules("ep2d_zero"))
    with use_sharding(ctx), mesh16:
        shard, _ = jax.jit(lambda p, x: moe_mod.apply(p, x, cfg))(params, x)
    err = float(jnp.abs(local - shard).max())
    assert err < 1e-4 * max(1.0, float(jnp.abs(local).max())), err
    print("ep2d_zero equivalence ok", err)
    """)
