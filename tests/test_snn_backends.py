"""Backend equivalence: the time-batched layer pipeline ("batched" /
"pallas") must reproduce the timestep-outer scan ("ref") exactly —
identical spike counts, logits to float tolerance — including through
CBWS-permuted weights (scheduling never changes the network function)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_snn
from repro.core import build_schedule, init_snn, snn_apply
from repro.core.neuron import lif_init
from repro.core.snn_layers import spiking_conv_step
from repro.core.snn_model import layer_shapes


def _tiny_mnist_cfg():
    return dataclasses.replace(
        get_snn("snn-mnist"), input_hw=(8, 8), conv_channels=(8, 8),
        timesteps=3, num_spe_clusters=4)


def _tiny_seg_cfg():
    return dataclasses.replace(
        get_snn("snn-seg"), input_hw=(6, 8), conv_channels=(4, 1),
        timesteps=2, num_spe_clusters=2)


def _assert_outputs_match(a, b, logits_tol=1e-5):
    np.testing.assert_allclose(np.asarray(a.logits), np.asarray(b.logits),
                               atol=logits_tol, rtol=logits_tol)
    for ca, cb in zip(a.spike_counts, b.spike_counts):
        np.testing.assert_array_equal(np.asarray(ca), np.asarray(cb))
    for ca, cb in zip(a.timestep_counts, b.timestep_counts):
        np.testing.assert_array_equal(np.asarray(ca), np.asarray(cb))
    for ta, tb in zip(a.spike_totals, b.spike_totals):
        assert float(ta) == float(tb)


@pytest.mark.parametrize("backend", ["batched", "pallas"])
def test_classification_backends_match_ref(backend):
    cfg = _tiny_mnist_cfg()
    params = init_snn(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 8, 8, 1))
    want = snn_apply(params, x, cfg, backend="ref")
    got = snn_apply(params, x, cfg, backend=backend)
    _assert_outputs_match(want, got)


@pytest.mark.parametrize("backend", ["batched", "pallas"])
def test_segmentation_backends_match_ref(backend):
    cfg = _tiny_seg_cfg()
    params = init_snn(jax.random.PRNGKey(2), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(3), (1, 6, 8, 3))
    want = snn_apply(params, x, cfg, backend="ref")
    got = snn_apply(params, x, cfg, backend=backend)
    _assert_outputs_match(want, got)


def test_pallas_backend_with_cbws_schedule_matches_ref():
    """CBWS-permuted kernel lanes (core.scheduler) leave logits AND the
    canonical-order spike counts unchanged."""
    cfg = _tiny_mnist_cfg()
    params = init_snn(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 8, 8, 1))
    sched = build_schedule(params, cfg, "aprc+cbws")
    want = snn_apply(params, x, cfg, backend="ref")
    got = snn_apply(params, x, cfg, backend="pallas", schedule=sched)
    _assert_outputs_match(want, got)


def test_pre_encoded_spike_train_backends_match_ref():
    """5-D input (T, B, H, W, Cin): no first-layer hoist, pure (T,B) fold."""
    cfg = _tiny_mnist_cfg()
    params = init_snn(jax.random.PRNGKey(4), cfg)
    z = (jax.random.uniform(jax.random.PRNGKey(5),
                            (cfg.timesteps, 2, 8, 8, 1)) < 0.4
         ).astype(jnp.float32)
    want = snn_apply(params, z, cfg, backend="ref")
    for backend in ("batched", "pallas"):
        _assert_outputs_match(want, snn_apply(params, z, cfg, backend=backend))


def test_time_batched_is_jittable_and_differentiable():
    """backend="batched" keeps the surrogate-gradient path intact."""
    cfg = _tiny_mnist_cfg()
    params = init_snn(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 8, 8, 1))

    @jax.jit
    def loss(p):
        return jnp.sum(snn_apply(p, x, cfg, backend="batched").logits ** 2)

    g = jax.grad(loss)(params)
    leaves = jax.tree_util.tree_leaves(g)
    assert all(bool(jnp.isfinite(l).all()) for l in leaves)
    assert any(float(jnp.abs(l).max()) > 0 for l in leaves)


def test_spiking_conv_step_pallas_matches_ref():
    """The per-timestep streaming entry point honours the backend switch."""
    cfg = _tiny_mnist_cfg()
    params = init_snn(jax.random.PRNGKey(0), cfg)["conv"][0]
    b = 2
    spikes = (jax.random.uniform(jax.random.PRNGKey(6), (b, 8, 8, 1)) < 0.3
              ).astype(jnp.float32)
    state = lif_init((b,) + layer_shapes(cfg)[0])
    st_ref, s_ref = spiking_conv_step(params, state, spikes, aprc=cfg.aprc,
                                      v_th=cfg.v_threshold)
    st_pal, s_pal = spiking_conv_step(params, state, spikes, aprc=cfg.aprc,
                                      v_th=cfg.v_threshold, backend="pallas",
                                      num_groups=2)
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_pal))
    np.testing.assert_allclose(np.asarray(st_ref.v), np.asarray(st_pal.v),
                               atol=1e-5)


def test_unknown_backend_raises():
    cfg = _tiny_mnist_cfg()
    params = init_snn(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (1, 8, 8, 1))
    with pytest.raises(ValueError, match="backend"):
        snn_apply(params, x, cfg, backend="tpu")
