"""Backend equivalence: the time-batched layer pipeline ("batched" /
"pallas") must reproduce the timestep-outer scan ("ref") exactly —
identical spike counts, logits to float tolerance — including through
CBWS-permuted weights (scheduling never changes the network function).

Gradient parity: all three backends carry the same surrogate gradient
(the fused kernel's custom_vjp must agree with the ref scan's BPTT to
float tolerance), the fused kernel's VJP passes a finite-difference check,
and the non-differentiable ``heaviside`` fails loudly under ``jax.grad``
instead of silently returning zeros."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_snn
from repro.core import build_schedule, init_snn, snn_apply
from repro.core.neuron import lif_init
from repro.core.snn_layers import spiking_conv_step
from repro.core.snn_model import layer_shapes
from repro.core.surrogate import NonDifferentiableSpikeError, heaviside


def _tiny_mnist_cfg():
    return dataclasses.replace(
        get_snn("snn-mnist"), input_hw=(8, 8), conv_channels=(8, 8),
        timesteps=3, num_spe_clusters=4)


def _tiny_seg_cfg():
    return dataclasses.replace(
        get_snn("snn-seg"), input_hw=(6, 8), conv_channels=(4, 1),
        timesteps=2, num_spe_clusters=2)


def _assert_outputs_match(a, b, logits_tol=1e-5):
    np.testing.assert_allclose(np.asarray(a.logits), np.asarray(b.logits),
                               atol=logits_tol, rtol=logits_tol)
    for ca, cb in zip(a.spike_counts, b.spike_counts):
        np.testing.assert_array_equal(np.asarray(ca), np.asarray(cb))
    for ca, cb in zip(a.timestep_counts, b.timestep_counts):
        np.testing.assert_array_equal(np.asarray(ca), np.asarray(cb))
    for ta, tb in zip(a.spike_totals, b.spike_totals):
        assert float(ta) == float(tb)


@pytest.mark.parametrize("backend", ["batched", "pallas"])
def test_classification_backends_match_ref(backend):
    cfg = _tiny_mnist_cfg()
    params = init_snn(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 8, 8, 1))
    want = snn_apply(params, x, cfg, backend="ref")
    got = snn_apply(params, x, cfg, backend=backend)
    _assert_outputs_match(want, got)


@pytest.mark.parametrize("backend", ["batched", "pallas"])
def test_segmentation_backends_match_ref(backend):
    cfg = _tiny_seg_cfg()
    params = init_snn(jax.random.PRNGKey(2), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(3), (1, 6, 8, 3))
    want = snn_apply(params, x, cfg, backend="ref")
    got = snn_apply(params, x, cfg, backend=backend)
    _assert_outputs_match(want, got)


def test_pallas_backend_with_cbws_schedule_matches_ref():
    """CBWS-permuted kernel lanes (core.scheduler) leave logits AND the
    canonical-order spike counts unchanged."""
    cfg = _tiny_mnist_cfg()
    params = init_snn(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 8, 8, 1))
    sched = build_schedule(params, cfg, "aprc+cbws")
    want = snn_apply(params, x, cfg, backend="ref")
    got = snn_apply(params, x, cfg, backend="pallas", schedule=sched)
    _assert_outputs_match(want, got)


def test_pre_encoded_spike_train_backends_match_ref():
    """5-D input (T, B, H, W, Cin): no first-layer hoist, pure (T,B) fold."""
    cfg = _tiny_mnist_cfg()
    params = init_snn(jax.random.PRNGKey(4), cfg)
    z = (jax.random.uniform(jax.random.PRNGKey(5),
                            (cfg.timesteps, 2, 8, 8, 1)) < 0.4
         ).astype(jnp.float32)
    want = snn_apply(params, z, cfg, backend="ref")
    for backend in ("batched", "pallas"):
        _assert_outputs_match(want, snn_apply(params, z, cfg, backend=backend))


def test_time_batched_is_jittable_and_differentiable():
    """backend="batched" keeps the surrogate-gradient path intact."""
    cfg = _tiny_mnist_cfg()
    params = init_snn(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 8, 8, 1))

    @jax.jit
    def loss(p):
        return jnp.sum(snn_apply(p, x, cfg, backend="batched").logits ** 2)

    g = jax.grad(loss)(params)
    leaves = jax.tree_util.tree_leaves(g)
    assert all(bool(jnp.isfinite(l).all()) for l in leaves)
    assert any(float(jnp.abs(l).max()) > 0 for l in leaves)


def test_spiking_conv_step_pallas_matches_ref():
    """The per-timestep streaming entry point honours the backend switch."""
    cfg = _tiny_mnist_cfg()
    params = init_snn(jax.random.PRNGKey(0), cfg)["conv"][0]
    b = 2
    spikes = (jax.random.uniform(jax.random.PRNGKey(6), (b, 8, 8, 1)) < 0.3
              ).astype(jnp.float32)
    state = lif_init((b,) + layer_shapes(cfg)[0])
    st_ref, s_ref = spiking_conv_step(params, state, spikes, aprc=cfg.aprc,
                                      v_th=cfg.v_threshold)
    st_pal, s_pal = spiking_conv_step(params, state, spikes, aprc=cfg.aprc,
                                      v_th=cfg.v_threshold, backend="pallas",
                                      num_groups=2)
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_pal))
    np.testing.assert_allclose(np.asarray(st_ref.v), np.asarray(st_pal.v),
                               atol=1e-5)


def test_unknown_backend_raises():
    cfg = _tiny_mnist_cfg()
    params = init_snn(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (1, 8, 8, 1))
    with pytest.raises(ValueError, match="backend"):
        snn_apply(params, x, cfg, backend="tpu")


def test_channel_mismatch_raises_eagerly():
    # 2-channel frames against a 1-channel config: the batched path's
    # implicit-GEMM conv would silently slice the extra channel away and
    # the ref scan would raise deep inside jax — snn_apply must reject
    # the frames up front, for every backend
    cfg = _tiny_mnist_cfg()
    params = init_snn(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(2), (2, 8, 8, 2))
    for backend in ("ref", "batched"):
        with pytest.raises(ValueError, match="input_channels"):
            snn_apply(params, x, cfg, backend=backend)


def test_spiking_conv_step_accepts_batched():
    """Per-timestep the time-batched backend IS the ref math — the step
    entry point must accept the name snn_apply advertises."""
    cfg = _tiny_mnist_cfg()
    params = init_snn(jax.random.PRNGKey(0), cfg)["conv"][0]
    spikes = (jax.random.uniform(jax.random.PRNGKey(6), (2, 8, 8, 1)) < 0.3
              ).astype(jnp.float32)
    state = lif_init((2,) + layer_shapes(cfg)[0])
    st_ref, s_ref = spiking_conv_step(params, state, spikes, aprc=cfg.aprc,
                                      v_th=cfg.v_threshold)
    st_bat, s_bat = spiking_conv_step(params, state, spikes, aprc=cfg.aprc,
                                      v_th=cfg.v_threshold, backend="batched")
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_bat))
    np.testing.assert_array_equal(np.asarray(st_ref.v), np.asarray(st_bat.v))


def test_spiking_conv_step_unknown_backend_names_valid_set():
    cfg = _tiny_mnist_cfg()
    params = init_snn(jax.random.PRNGKey(0), cfg)["conv"][0]
    spikes = jnp.zeros((1, 8, 8, 1))
    state = lif_init((1,) + layer_shapes(cfg)[0])
    with pytest.raises(ValueError, match=r"(?s)ref.*batched.*pallas.*snn_apply"):
        spiking_conv_step(params, state, spikes, aprc=cfg.aprc,
                          v_th=cfg.v_threshold, backend="fpga")


# ---------------------------------------------------------------------------
# Gradient parity + VJP correctness
# ---------------------------------------------------------------------------


def _grad_of_loss(params, x, y, cfg, backend, **kw):
    def loss(p):
        out = snn_apply(p, x, cfg, backend=backend, **kw)
        logp = jax.nn.log_softmax(out.logits.astype(jnp.float32))
        return -logp[jnp.arange(logp.shape[0]), y].mean()

    return jax.grad(loss)(params)


def _assert_grads_close(a, b, atol=5e-5, rtol=5e-4):
    flat_a, _ = jax.tree_util.tree_flatten(a)
    flat_b, _ = jax.tree_util.tree_flatten(b)
    assert flat_a and len(flat_a) == len(flat_b)
    for ga, gb in zip(flat_a, flat_b):
        np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                                   atol=atol, rtol=rtol)


@pytest.mark.parametrize("backend", ["batched", "pallas"])
def test_classification_gradient_parity_vs_ref(backend):
    """jax.grad of the training loss agrees ref vs time-batched backends —
    the fused kernel's custom_vjp is the ref scan's surrogate BPTT."""
    cfg = _tiny_mnist_cfg()
    params = init_snn(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 8, 8, 1))
    y = jnp.array([3, 7])
    want = _grad_of_loss(params, x, y, cfg, "ref")
    got = _grad_of_loss(params, x, y, cfg, backend)
    _assert_grads_close(want, got)


@pytest.mark.parametrize("kind", ["fast_sigmoid", "triangle", "arctan"])
def test_gradient_parity_all_surrogate_kinds(kind):
    """The selectable surrogate (kind x alpha) threads through the pallas
    custom_vjp — previously the pallas path dropped surrogate_alpha."""
    cfg = _tiny_mnist_cfg()
    params = init_snn(jax.random.PRNGKey(0), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, 8, 8, 1))
    y = jnp.array([3, 7])
    kw = dict(surrogate_alpha=4.0, surrogate_kind=kind)
    want = _grad_of_loss(params, x, y, cfg, "ref", **kw)
    got = _grad_of_loss(params, x, y, cfg, "pallas", **kw)
    _assert_grads_close(want, got)
    # a different surrogate must actually change the gradient
    other = _grad_of_loss(params, x, y, cfg, "pallas",
                          surrogate_alpha=40.0, surrogate_kind=kind)
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), want, other)
    assert max(jax.tree_util.tree_leaves(diffs)) > 1e-6


@pytest.mark.parametrize("backend", ["batched", "pallas"])
def test_spike_train_input_gradient_parity(backend):
    """5-D pre-encoded input: every layer (no hoist) runs the fused kernel
    under the pallas backend, so this exercises its VJP end to end."""
    cfg = _tiny_mnist_cfg()
    params = init_snn(jax.random.PRNGKey(4), cfg)
    z = (jax.random.uniform(jax.random.PRNGKey(5),
                            (cfg.timesteps, 2, 8, 8, 1)) < 0.4
         ).astype(jnp.float32)
    y = jnp.array([0, 9])
    want = _grad_of_loss(params, z, y, cfg, "ref")
    got = _grad_of_loss(params, z, y, cfg, backend)
    _assert_grads_close(want, got)


@pytest.mark.parametrize("backend", ["batched", "pallas"])
def test_segmentation_gradient_parity_vs_ref(backend):
    cfg = _tiny_seg_cfg()
    params = init_snn(jax.random.PRNGKey(2), cfg)
    x = jax.random.uniform(jax.random.PRNGKey(3), (1, 6, 8, 3))

    def loss(p, bk):
        return jnp.sum(snn_apply(p, x, cfg, backend=bk).logits ** 2)

    want = jax.grad(lambda p: loss(p, "ref"))(params)
    got = jax.grad(lambda p: loss(p, backend))(params)
    _assert_grads_close(want, got)


def test_pallas_backward_kernel_matches_xla_fallback():
    """bwd="pallas" (the mirror Pallas kernels) and bwd="xla" (the
    fallback) compute the same VJP."""
    from repro.kernels import ops

    T, B, H, W, Cin, Cout = 3, 2, 6, 7, 2, 4
    spikes = (jax.random.uniform(jax.random.PRNGKey(0),
                                 (T, B, H, W, Cin)) < 0.4).astype(jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, Cin, Cout)) * 0.3
    b = jnp.linspace(-0.1, 0.1, Cout)
    v0 = jnp.zeros((B, H + 2, W + 2, Cout))
    proj = jax.random.normal(jax.random.PRNGKey(2), (T, B, H + 2, W + 2, Cout))

    def loss(args, bwd):
        sp, v0_, w_, b_ = args
        s, vf = ops.spiking_conv_lif(sp, v0_, w_, b_, v_th=1.0, aprc=True,
                                     num_groups=2, bwd=bwd)
        return (s * proj).sum() + (vf ** 2).sum()

    g_x = jax.grad(lambda a: loss(a, "xla"))((spikes, v0, w, b))
    g_p = jax.grad(lambda a: loss(a, "pallas"))((spikes, v0, w, b))
    _assert_grads_close(g_x, g_p, atol=1e-5, rtol=1e-5)


def test_fused_kernel_vjp_finite_difference():
    """Finite-difference check of the fused kernel's VJP on a small
    (T, B, H, W, C) case.

    The spike nonlinearity is a step (FD through it measures the true
    zero-a.e. derivative, not the surrogate), so the check runs in the
    no-spike regime: v_th far above any membrane and a large alpha make
    the surrogate factor ~1e-7, the network exactly linear in every input
    (s == 0 everywhere), and the VJP's conv/BPTT chain — transposed taps,
    dw/db tap matmuls, dv0 carry — must match central differences of the
    true function to first order."""
    from repro.kernels import ops

    T, B, H, W, Cin, Cout = 3, 2, 5, 6, 2, 4
    key = jax.random.PRNGKey(0)
    spikes = jax.random.uniform(key, (T, B, H, W, Cin))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, Cin, Cout)) * 0.2
    b = jnp.linspace(-0.1, 0.1, Cout)
    v0 = jax.random.normal(jax.random.PRNGKey(2), (B, H + 2, W + 2, Cout)) * .1
    proj = jax.random.normal(jax.random.PRNGKey(3), (B, H + 2, W + 2, Cout))

    def f(args):
        sp, v0_, w_, b_ = args
        s, vf = ops.spiking_conv_lif(sp, v0_, w_, b_, v_th=30.0, aprc=True,
                                     num_groups=2, surrogate_alpha=100.0)
        # sanity: genuinely in the no-spike linear regime
        return (vf * proj).sum(), s.sum()

    args = (spikes, v0, w, b)
    (_, n_spikes) = f(args)
    assert float(n_spikes) == 0.0
    grads = jax.grad(lambda a: f(a)[0])(args)

    eps = 1e-3
    rng = np.random.default_rng(0)
    for i, (a, g) in enumerate(zip(args, grads)):
        d = jnp.asarray(rng.standard_normal(a.shape), a.dtype)
        plus = list(args)
        minus = list(args)
        plus[i] = a + eps * d
        minus[i] = a - eps * d
        fd = (float(f(tuple(plus))[0]) - float(f(tuple(minus))[0])) / (2 * eps)
        analytic = float((g * d).sum())
        np.testing.assert_allclose(analytic, fd, rtol=2e-3, atol=2e-3)


def test_heaviside_raises_under_grad_not_silent_zeros():
    """Regression: the inference-only Heaviside used to differentiate to
    silent zeros; now it must fail loudly and name the differentiable
    route."""
    x = jnp.linspace(-1.0, 1.0, 8)
    assert float(heaviside(x).sum()) == 4.0          # forward still works
    with pytest.raises(NonDifferentiableSpikeError,
                       match=r"(?s)spike_fn.*ref.*batched.*pallas"):
        jax.grad(lambda v: heaviside(v).sum())(x)
    # the loud failure also fires under jit tracing
    with pytest.raises(NonDifferentiableSpikeError):
        jax.jit(jax.grad(lambda v: heaviside(v).sum()))(x)


def test_batched_backend_training_tracks_ref():
    """A short real training run (same data, same init): the time-batched
    backend's loss trajectory must track the seed scan step for step, and
    both must actually learn.  (The full same-accuracy-band run lives in
    examples/snn_mnist_train.py --backend batched — too slow for tier-1.)"""
    from repro.core import make_train_step
    from repro.data.synthetic import mnist_like

    cfg = dataclasses.replace(get_snn("snn-mnist"), timesteps=3)
    x, y = mnist_like(16, seed=0)
    x, y = jnp.asarray(x), jnp.asarray(y)
    losses = {}
    for backend in ("ref", "batched"):
        params = init_snn(jax.random.PRNGKey(0), cfg)
        mom = jax.tree.map(jnp.zeros_like, params)
        step = jax.jit(make_train_step(cfg, backend=backend, lr=1e-2))
        traj = []
        for _ in range(10):                 # overfit one fixed batch
            params, mom, loss = step(params, mom, x, y)
            traj.append(float(loss))
        losses[backend] = traj
    np.testing.assert_allclose(losses["batched"], losses["ref"],
                               rtol=1e-3, atol=1e-3)
    assert losses["batched"][-1] < losses["batched"][0] - 0.05, losses
