"""End-to-end behaviour of the paper's system: APRC + CBWS on the Skydiver
performance model — reproduces the Fig. 7 mechanism (balance hierarchy
none < APRC+CBWS, with CBWS-alone degraded by bad predictions) and the
throughput-gain claim."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_snn
from repro.core import (build_schedule, init_snn, measure_balance,
                        permute_conv_params, snn_apply)
from repro.core.balance import throughput_gain
from repro.perfmodel import XC7Z045, simulate_network
import pytest

pytestmark = pytest.mark.slow  # heavyweight; excluded from default tier-1 run


def _small_seg_cfg():
    cfg = get_snn("snn-seg")
    return dataclasses.replace(cfg, input_hw=(20, 40), timesteps=6)


def _run_and_collect(cfg, params, x):
    out = snn_apply(params, x, cfg)
    # input workload of layer l = output spike counts of layer l-1
    per_layer = []
    t = cfg.timesteps
    b, h, w, c = x.shape
    # layer 0 input: encoded frame treated as dense events
    dense0 = np.full((t, c), float(b * h * w) / 1.0 / c)
    per_layer.append(dense0)
    for l in range(len(cfg.conv_channels) - 1):
        per_layer.append(np.asarray(out.timestep_counts[l]))
    return out, per_layer


def test_balance_hierarchy_and_throughput():
    cfg = _small_seg_cfg()
    key = jax.random.PRNGKey(0)
    params = init_snn(key, cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1), (2, *cfg.input_hw,
                                                   cfg.input_channels))
    out, per_layer = _run_and_collect(cfg, params, x)

    results = {}
    for mode in ("none", "aprc+cbws"):
        scheds = build_schedule(params, cfg, mode)
        perf = simulate_network(
            cfg, per_layer,
            in_partitions=[s.in_partition for s in scheds],
            out_partitions=[s.out_partition for s in scheds],
            hw=XC7Z045)
        results[mode] = perf

    b_none = results["none"].balance
    b_cbws = results["aprc+cbws"].balance
    assert b_cbws > b_none, (b_cbws, b_none)
    # unit scale: random weights, 6 timesteps, 1-channel final layer — the
    # paper-scale bands (>90%) are exercised by benchmarks/fig7_balance.py
    assert b_cbws > 0.6, b_cbws

    gain = throughput_gain(b_cbws, b_none)
    fps_none = results["none"].fps(XC7Z045)
    fps_cbws = results["aprc+cbws"].fps(XC7Z045)
    assert fps_cbws > fps_none
    # implied and simulated gains agree to ~15%
    assert abs(gain - fps_cbws / fps_none) / gain < 0.3


def test_channel_permutation_preserves_network_function():
    cfg = get_snn("snn-mnist")
    cfg = dataclasses.replace(cfg, timesteps=4)
    key = jax.random.PRNGKey(0)
    params = init_snn(key, cfg)
    x = jax.random.uniform(jax.random.PRNGKey(2), (2, 28, 28, 1))
    base = snn_apply(params, x, cfg)
    scheds = build_schedule(params, cfg, "aprc+cbws")
    permuted = permute_conv_params(params, scheds)
    out = snn_apply(permuted, x, cfg)
    np.testing.assert_allclose(np.asarray(base.logits),
                               np.asarray(out.logits), atol=1e-5)
    # totals preserved per layer (channels just reordered)
    for a, b in zip(base.spike_totals, out.spike_totals):
        np.testing.assert_allclose(float(a), float(b))


def test_perfmodel_energy_and_gsops_sane():
    cfg = _small_seg_cfg()
    key = jax.random.PRNGKey(0)
    params = init_snn(key, cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1),
                           (1, *cfg.input_hw, cfg.input_channels))
    out, per_layer = _run_and_collect(cfg, params, x)
    scheds = build_schedule(params, cfg, "aprc+cbws")
    perf = simulate_network(cfg, per_layer,
                            [s.in_partition for s in scheds],
                            [s.out_partition for s in scheds])
    assert perf.total_sops > 0
    assert 0 < perf.fps(XC7Z045) < 1e7
    assert perf.energy_j(XC7Z045) > 0
    assert perf.gsops(XC7Z045) > 0
