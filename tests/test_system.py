"""End-to-end behaviour of the paper's system: APRC + CBWS on the Skydiver
performance model — reproduces the Fig. 7 mechanism (balance hierarchy
none <= cbws <= aprc+cbws) and the throughput-gain claim.

The networks run with ``skew_channels``-biased weights: random-init filters
have near-uniform magnitudes (nothing for a scheduler to balance, and the
hierarchy came out of the noise — the seed failure), while the lognormal
channel skew reproduces the trained-net operating regime the paper measures
(Fig. 2b) and makes the hierarchy deterministic."""
import dataclasses

import jax
import numpy as np

from repro.config import get_snn
from repro.core import (build_schedule, init_snn, permute_conv_params,
                        snn_apply)
from repro.core.balance import throughput_gain
from repro.core.snn_model import skew_channels
from repro.perfmodel import XC7Z045, simulate_network


def _small_seg_cfg(**over):
    cfg = get_snn("snn-seg")
    return dataclasses.replace(cfg, input_hw=(20, 40), timesteps=6, **over)


def _run_and_collect(cfg, params, x):
    out = snn_apply(params, x, cfg, backend="batched")
    # input workload of layer l = output spike counts of layer l-1
    t = cfg.timesteps
    b, h, w, c = x.shape
    # layer 0 input: encoded frame treated as dense events
    per_layer = [np.full((t, c), float(b * h * w) / c)]
    for l in range(len(cfg.conv_channels) - 1):
        per_layer.append(np.asarray(out.timestep_counts[l]))
    return out, per_layer


def _skewed_params(cfg):
    return skew_channels(init_snn(jax.random.PRNGKey(0), cfg),
                         sigma=1.2, seed=1)


def _simulate(cfg, params, x, sched_mode):
    _, per_layer = _run_and_collect(cfg, params, x)
    scheds = build_schedule(params, cfg, sched_mode)
    return simulate_network(cfg, per_layer,
                            in_partitions=[s.in_partition for s in scheds],
                            out_partitions=[s.out_partition for s in scheds],
                            hw=XC7Z045)


def test_balance_hierarchy_and_throughput():
    """Fig. 7's three bars: 'none' stripes channels naively, 'cbws' runs
    Algorithm 1 on the unmodified (SAME-pad) net, 'aprc+cbws' on the
    APRC-modified net where Eq. (5) makes the predictions proportional."""
    results = {}
    for mode in ("none", "cbws", "aprc+cbws"):
        cfg = _small_seg_cfg(aprc=(mode == "aprc+cbws"))
        params = _skewed_params(cfg)
        x = jax.random.uniform(jax.random.PRNGKey(1),
                               (2, *cfg.input_hw, cfg.input_channels))
        sched_mode = "none" if mode == "none" else "aprc+cbws"
        results[mode] = _simulate(cfg, params, x, sched_mode)

    b = {m: p.balance_spartus for m, p in results.items()}
    assert b["none"] <= b["cbws"] + 1e-9, b
    assert b["cbws"] <= b["aprc+cbws"] + 1e-9, b
    assert b["none"] < b["aprc+cbws"], b
    # unit scale: skewed weights, 6 timesteps, 1-channel final layer — the
    # paper-scale bands (>90%) are exercised by benchmarks/fig7_balance.py
    assert b["aprc+cbws"] > 0.6, b

    # throughput claim, same (APRC) net so FPS is apples-to-apples:
    # schedule-only change none -> aprc+cbws
    cfg = _small_seg_cfg()
    params = _skewed_params(cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1),
                           (2, *cfg.input_hw, cfg.input_channels))
    none = _simulate(cfg, params, x, "none")
    both = _simulate(cfg, params, x, "aprc+cbws")
    assert both.balance > none.balance
    fps_none, fps_both = none.fps(XC7Z045), both.fps(XC7Z045)
    assert fps_both > fps_none
    # implied and simulated gains agree to ~30%
    gain = throughput_gain(both.balance, none.balance)
    assert abs(gain - fps_both / fps_none) / gain < 0.3


def test_channel_permutation_preserves_network_function():
    cfg = get_snn("snn-mnist")
    cfg = dataclasses.replace(cfg, timesteps=4)
    key = jax.random.PRNGKey(0)
    params = init_snn(key, cfg)
    x = jax.random.uniform(jax.random.PRNGKey(2), (2, 28, 28, 1))
    base = snn_apply(params, x, cfg)
    scheds = build_schedule(params, cfg, "aprc+cbws")
    permuted = permute_conv_params(params, scheds)
    out = snn_apply(permuted, x, cfg)
    np.testing.assert_allclose(np.asarray(base.logits),
                               np.asarray(out.logits), atol=1e-5)
    # totals preserved per layer (channels just reordered)
    for a, b in zip(base.spike_totals, out.spike_totals):
        np.testing.assert_allclose(float(a), float(b))


def test_perfmodel_energy_and_gsops_sane():
    cfg = _small_seg_cfg()
    params = _skewed_params(cfg)
    x = jax.random.uniform(jax.random.PRNGKey(1),
                           (1, *cfg.input_hw, cfg.input_channels))
    perf = _simulate(cfg, params, x, "aprc+cbws")
    assert perf.total_sops > 0
    assert 0 < perf.fps(XC7Z045) < 1e7
    assert perf.energy_j(XC7Z045) > 0
    assert perf.gsops(XC7Z045) > 0
